"""Layer-2: the CoEdge-RAG PPO policy network and its update step, in JAX.

Architecture (paper §V-A "Implementation Settings"): four fully-connected
layers 256-128-64-N over the 256-d query embedding, with a residual
connection and layer normalization on the equal-width first layer.

Two graphs are AOT-lowered for the Rust coordinator (aot.py):

* ``policy_fwd``  — the request-path graph. Uses the Layer-1 **Pallas**
  kernels (fused dense+ReLU, layer norm, row softmax).
* ``ppo_update``  — the training-path graph: clipped policy-only PPO
  surrogate (paper Eq. 11) + entropy bonus, differentiated with
  ``jax.grad`` and applied with an inlined Adam step. The forward math is
  the jnp reference path, which python/tests assert is numerically
  identical to the Pallas path — so the gradients match the serving
  forward.

Rust owns the parameters: both graphs are pure functions
``(params, ...) -> outputs`` with parameters passed as flat input lists in
``PARAM_NAMES`` order and returned in the same order by the update.
"""

import jax
import jax.numpy as jnp

from .kernels import dense, layer_norm, row_softmax
from .kernels.ref import dense_ref, layer_norm_ref, row_softmax_ref

# Model dimensions. EMBED_DIM must match rust/src/text/embed.rs::EMBED_DIM.
EMBED_DIM = 256
HIDDEN = (256, 128, 64)

# PPO hyper-parameters (paper §V-A): Adam lr 3e-4, clip eps 0.02.
LEARNING_RATE = 3e-4
CLIP_EPS = 0.02
ENTROPY_BETA = 0.01
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
LN_EPS = 1e-5

# Flat parameter ordering shared with the Rust runtime.
PARAM_NAMES = (
    "w1", "b1", "ln_g", "ln_b",
    "w2", "b2",
    "w3", "b3",
    "w4", "b4",
)


def param_shapes(n_actions: int):
    """Shapes in PARAM_NAMES order."""
    h1, h2, h3 = HIDDEN
    return (
        (EMBED_DIM, h1), (h1,), (h1,), (h1,),
        (h1, h2), (h2,),
        (h2, h3), (h3,),
        (h3, n_actions), (n_actions,),
    )


def init_params(key, n_actions: int):
    """He-uniform init, biases zero; returns the flat param list."""
    shapes = param_shapes(n_actions)
    params = []
    for name, shape in zip(PARAM_NAMES, shapes):
        if name.startswith("w"):
            key, sub = jax.random.split(key)
            fan_in = shape[0]
            lim = (6.0 / fan_in) ** 0.5
            params.append(jax.random.uniform(sub, shape, jnp.float32, -lim, lim))
        elif name == "ln_g":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _forward(params, x, *, pallas: bool):
    """Logits of the policy network; pallas=True uses Layer-1 kernels."""
    w1, b1, ln_g, ln_b, w2, b2, w3, b3, w4, b4 = params
    d = dense if pallas else dense_ref
    ln = layer_norm if pallas else layer_norm_ref
    h = d(x, w1, b1, relu=True)
    h = ln(h + x, ln_g, ln_b, eps=LN_EPS)  # residual on the 256-wide layer
    h = d(h, w2, b2, relu=True)
    h = d(h, w3, b3, relu=True)
    return d(h, w4, b4, relu=False)


def policy_fwd(params, x):
    """Request-path forward: action probabilities, via Pallas kernels.

    x: (B, EMBED_DIM) float32 -> probs: (B, N) float32.
    """
    logits = _forward(params, x, pallas=True)
    return (row_softmax(logits),)


def policy_fwd_ref(params, x):
    """jnp-only forward (used by tests and by the update's gradient path)."""
    logits = _forward(params, x, pallas=False)
    return (row_softmax_ref(logits),)


def _log_softmax(logits):
    m = jnp.max(logits, axis=-1, keepdims=True)
    s = logits - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def ppo_loss(params, x, action_onehot, reward, old_logp, mask):
    """Policy-only clipped PPO objective with entropy bonus (Eq. 11).

    reward is the batch-standardized feedback f̄ (Eq. 10), computed by the
    Rust coordinator. Returns scalar loss (to minimize) and mean entropy.
    """
    logits = _forward(params, x, pallas=False)
    logp = _log_softmax(logits)
    chosen_logp = jnp.sum(logp * action_onehot, axis=-1)
    ratio = jnp.exp(chosen_logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS)
    surrogate = jnp.minimum(ratio * reward, clipped * reward)
    probs = jnp.exp(logp)
    entropy = -jnp.sum(probs * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    j = jnp.sum(surrogate * mask) / denom + ENTROPY_BETA * jnp.sum(entropy * mask) / denom
    return -j, jnp.sum(entropy * mask) / denom


def ppo_update(params, adam_m, adam_v, step, x, action_onehot, reward, old_logp, mask):
    """One Adam step on the PPO loss.

    All state is explicit: returns (new_params…, new_m…, new_v…, loss,
    entropy) as a flat tuple so the AOT artifact is a pure function the
    Rust runtime can thread state through.

    step: float32 scalar, 1-based Adam timestep.
    """
    (loss, entropy), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, x, action_onehot, reward, old_logp, mask
    )
    t = step
    new_params, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, adam_m, adam_v):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(g)
        mhat = m2 / (1.0 - ADAM_B1 ** t)
        vhat = v2 / (1.0 - ADAM_B2 ** t)
        new_params.append(p - LEARNING_RATE * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_params) + tuple(new_m) + tuple(new_v) + (loss, entropy)
