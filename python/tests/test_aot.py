"""AOT pipeline: artifacts lower, parse as HLO text, and manifest is sane."""

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "..", "artifacts")


def test_lower_policy_fwd_produces_hlo_text():
    text = aot.lower_policy_fwd(n_actions=3, batch=4)
    assert "HloModule" in text
    assert "f32[4,256]" in text  # input batch
    assert "f32[4,3]" in text    # output probs


def test_lower_ppo_update_produces_hlo_text():
    text = aot.lower_ppo_update(n_actions=3, batch=8)
    assert "HloModule" in text
    assert "f32[8,256]" in text
    # gradients of w1 appear as its shape somewhere in the update
    assert "f32[256,256]" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["embed_dim"] == model.EMBED_DIM
    assert man["param_names"] == list(model.PARAM_NAMES)
    assert len(man["artifacts"]) > 0
    for art in man["artifacts"]:
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
        shapes = [tuple(s) for s in art["param_shapes"]]
        assert shapes == list(model.param_shapes(art["n_actions"]))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_hyperparams_match_model():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    hp = man["hyperparams"]
    assert hp["learning_rate"] == model.LEARNING_RATE
    assert hp["clip_eps"] == model.CLIP_EPS
    assert hp["entropy_beta"] == model.ENTROPY_BETA
