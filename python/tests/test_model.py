"""Layer-2 correctness: policy forward invariants and PPO update behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

N = 4
B = 16


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(42), N)


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(7), (B, model.EMBED_DIM), jnp.float32)


def test_param_shapes_order(params):
    shapes = model.param_shapes(N)
    assert len(params) == len(model.PARAM_NAMES) == len(shapes)
    for p, s in zip(params, shapes):
        assert p.shape == s


def test_fwd_probs_simplex(params, x):
    probs = np.asarray(model.policy_fwd(params, x)[0])
    assert probs.shape == (B, N)
    assert (probs >= 0).all()
    np.testing.assert_allclose(probs.sum(axis=-1), np.ones(B), rtol=1e-5)


def test_fwd_pallas_matches_ref(params, x):
    a = np.asarray(model.policy_fwd(params, x)[0])
    b = np.asarray(model.policy_fwd_ref(params, x)[0])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_fwd_depends_on_input(params):
    x1 = jnp.ones((1, model.EMBED_DIM), jnp.float32) * 0.1
    x2 = -x1
    p1 = np.asarray(model.policy_fwd_ref(params, x1)[0])
    p2 = np.asarray(model.policy_fwd_ref(params, x2)[0])
    assert np.abs(p1 - p2).max() > 1e-4


def _update_args(params, x, actions, rewards):
    onehot = jax.nn.one_hot(actions, N, dtype=jnp.float32)
    probs = model.policy_fwd_ref(params, x)[0]
    old_logp = jnp.log(jnp.sum(probs * onehot, axis=-1) + 1e-12)
    mask = jnp.ones(x.shape[0], jnp.float32)
    zeros = [jnp.zeros_like(p) for p in params]
    return zeros, old_logp, onehot, mask


def test_ppo_update_shapes_and_state(params, x):
    actions = jnp.zeros(B, jnp.int32)
    rewards = jnp.ones(B, jnp.float32)
    zeros, old_logp, onehot, mask = _update_args(params, x, actions, rewards)
    out = model.ppo_update(params, zeros, [jnp.zeros_like(p) for p in params],
                           jnp.float32(1.0), x, onehot, rewards, old_logp, mask)
    npar = len(params)
    assert len(out) == 3 * npar + 2
    for p, q in zip(params, out[:npar]):
        assert p.shape == q.shape
    loss, entropy = out[-2], out[-1]
    assert np.isfinite(float(loss))
    assert float(entropy) > 0.0


def test_ppo_increases_rewarded_action_probability(params):
    """Repeatedly rewarding action 0 must raise its probability."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (B, model.EMBED_DIM), jnp.float32)
    p = [jnp.array(q) for q in params]
    m = [jnp.zeros_like(q) for q in p]
    v = [jnp.zeros_like(q) for q in p]
    actions = jnp.zeros(B, jnp.int32)
    rewards = jnp.ones(B, jnp.float32)  # standardized positive reward
    onehot = jax.nn.one_hot(actions, N, dtype=jnp.float32)
    mask = jnp.ones(B, jnp.float32)
    before = float(np.asarray(model.policy_fwd_ref(p, x)[0])[:, 0].mean())
    upd = jax.jit(model.ppo_update)
    for t in range(1, 60):
        probs = model.policy_fwd_ref(p, x)[0]
        old_logp = jnp.log(jnp.sum(probs * onehot, axis=-1) + 1e-12)
        out = upd(p, m, v, jnp.float32(t), x, onehot, rewards, old_logp, mask)
        npar = len(p)
        p = list(out[:npar])
        m = list(out[npar:2 * npar])
        v = list(out[2 * npar:3 * npar])
    after = float(np.asarray(model.policy_fwd_ref(p, x)[0])[:, 0].mean())
    assert after > before + 0.02, f"before={before:.4f} after={after:.4f}"


def test_ppo_clip_bounds_update_when_ratio_far(params, x):
    """With old_logp far from current, the clipped surrogate caps gradients:
    loss must stay finite and params move only slightly."""
    actions = jnp.zeros(B, jnp.int32)
    rewards = jnp.ones(B, jnp.float32)
    onehot = jax.nn.one_hot(actions, N, dtype=jnp.float32)
    old_logp = jnp.full((B,), -10.0, jnp.float32)  # ratio >> 1+eps
    mask = jnp.ones(B, jnp.float32)
    zeros = [jnp.zeros_like(q) for q in params]
    out = model.ppo_update(params, zeros, [jnp.zeros_like(q) for q in params],
                           jnp.float32(1.0), x, onehot, rewards, old_logp, mask)
    assert np.isfinite(float(out[-2]))


def test_mask_excludes_padding(params, x):
    """Masked-out rows must not affect the loss."""
    actions = jnp.zeros(B, jnp.int32)
    onehot = jax.nn.one_hot(actions, N, dtype=jnp.float32)
    probs = model.policy_fwd_ref(params, x)[0]
    old_logp = jnp.log(jnp.sum(probs * onehot, axis=-1) + 1e-12)
    rewards = jnp.ones(B, jnp.float32)
    half = jnp.concatenate([jnp.ones(B // 2), jnp.zeros(B // 2)]).astype(jnp.float32)
    # corrupt the masked rows' rewards wildly; loss must be unchanged
    r2 = rewards.at[B // 2:].set(1e6)
    l1, _ = model.ppo_loss(params, x, onehot, rewards, old_logp, half)
    l2, _ = model.ppo_loss(params, x, onehot, r2, old_logp, half)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_entropy_max_at_uniform():
    logits_uniform = jnp.zeros((1, N), jnp.float32)
    probs = jax.nn.softmax(logits_uniform)
    h = -jnp.sum(probs * jnp.log(probs))
    np.testing.assert_allclose(float(h), np.log(N), rtol=1e-6)
