"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including ragged tile edges around the 128-wide
blocks) and value scales; every kernel must match ref.py to fp32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, layer_norm, row_softmax
from compile.kernels.ref import dense_ref, layer_norm_ref, row_softmax_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale):
    return scale * jax.random.normal(key, shape, jnp.float32)


dims = st.sampled_from([1, 2, 3, 5, 16, 64, 127, 128, 129, 200, 256])
small_dims = st.sampled_from([1, 2, 3, 4, 6, 8, 64, 128])
scales = st.sampled_from([1e-2, 1.0, 10.0])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=30, deadline=None)
@given(b=dims, k=small_dims, n=small_dims, scale=scales, seed=seeds)
def test_dense_relu_matches_ref(b, k, n, scale, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k0, (b, k), scale)
    w = _rand(k1, (k, n), scale)
    bias = _rand(k2, (n,), scale)
    got = dense(x, w, bias, relu=True)
    want = dense_ref(x, w, bias, relu=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(b=dims, k=small_dims, n=small_dims, seed=seeds)
def test_dense_linear_matches_ref(b, k, n, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k0, (b, k), 1.0)
    w = _rand(k1, (k, n), 1.0)
    bias = _rand(k2, (n,), 1.0)
    got = dense(x, w, bias, relu=False)
    want = dense_ref(x, w, bias, relu=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=dims, d=st.sampled_from([2, 3, 64, 128, 256]), scale=scales, seed=seeds)
def test_layer_norm_matches_ref(b, d, scale, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k0, (b, d), scale)
    g = 1.0 + 0.1 * jax.random.normal(k1, (d,), jnp.float32)
    be = _rand(k2, (d,), 0.5)
    got = layer_norm(x, g, be)
    want = layer_norm_ref(x, g, be)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=dims, n=st.sampled_from([2, 3, 4, 6, 8, 33]), scale=scales, seed=seeds)
def test_row_softmax_matches_ref(b, n, scale, seed):
    x = _rand(jax.random.PRNGKey(seed), (b, n), scale)
    got = np.asarray(row_softmax(x))
    want = np.asarray(row_softmax_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got.sum(axis=-1), np.ones(b), rtol=1e-5)
    assert (got >= 0).all()


def test_layer_norm_row_stats():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 256), jnp.float32) * 5 + 3
    y = np.asarray(layer_norm(x, jnp.ones(256), jnp.zeros(256)))
    np.testing.assert_allclose(y.mean(axis=-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(y.std(axis=-1), np.ones(16), atol=1e-3)


def test_softmax_extreme_logits_stable():
    x = jnp.array([[1000.0, 0.0, -1000.0], [-1e6, -1e6, -1e6]], jnp.float32)
    y = np.asarray(row_softmax(x))
    assert np.isfinite(y).all()
    np.testing.assert_allclose(y.sum(axis=-1), [1.0, 1.0], rtol=1e-6)
    assert y[0, 0] > 0.999
    np.testing.assert_allclose(y[1], [1 / 3] * 3, rtol=1e-5)


def test_dense_relu_clamps_negative():
    x = -jnp.ones((4, 8), jnp.float32)
    w = jnp.eye(8, dtype=jnp.float32)
    b = jnp.zeros(8, jnp.float32)
    y = np.asarray(dense(x, w, b, relu=True))
    assert (y == 0).all()


def test_dense_shape_mismatch_raises():
    x = jnp.zeros((4, 8), jnp.float32)
    w = jnp.zeros((9, 3), jnp.float32)
    b = jnp.zeros(3, jnp.float32)
    with pytest.raises(AssertionError):
        dense(x, w, b)
